//! Communication patterns of the synthetic workloads (paper §5.2).
//!
//! Four patterns, quoted from the paper:
//!
//! * **Gather/Reduce** — "one process as the root process receives messages
//!   from other processes and other processes are just senders."
//! * **Bcast/Scatter** — "one process as the root process sends its messages
//!   to other processes and other processes are just receivers."
//! * **Linear** — "each process receives messages from a previous process and
//!   sends its messages to a next process."
//! * **All-to-All** — "each process sends messages to all other processes."
//!
//! Normative send semantics (DESIGN.md §9): the paper's `Message Count` is
//! the number of messages each *sender* transmits; destinations follow the
//! pattern's schedule (round-robin over the peer set where the pattern allows
//! more than one peer).
//!
//! Beyond the paper's four, [`Pattern::Stencil2d`] models the nearest-
//! neighbour halo exchange of grid codes — the bounded-degree pattern the
//! sparse traffic layer scales to thousands of processes — and
//! [`Pattern::Stencil3d`] its volumetric cousin (up to six neighbours on a
//! near-cubic grid), the topology-matched heavy communicator for 3-D torus
//! sweeps. Both are deliberately **not** part of [`Pattern::ALL`], which
//! stays the paper's Table-1 set so the builtin synthetic workloads and
//! generated test data are unchanged.

use crate::model::workload::ProcId;

/// Integer square root (largest `x` with `x * x <= n`).
fn isqrt(n: usize) -> usize {
    if n == 0 {
        return 0;
    }
    let mut x = (n as f64).sqrt() as usize;
    while (x + 1) * (x + 1) <= n {
        x += 1;
    }
    while x * x > n {
        x -= 1;
    }
    x
}

/// Integer cube root (largest `x` with `x * x * x <= n`).
fn icbrt(n: usize) -> usize {
    if n == 0 {
        return 0;
    }
    let mut x = (n as f64).cbrt() as usize;
    while (x + 1) * (x + 1) * (x + 1) <= n {
        x += 1;
    }
    while x * x * x > n {
        x -= 1;
    }
    x
}

/// Grid neighbours of `rank` on the near-square 2D stencil over `p` ranks:
/// `isqrt(p)` columns, row-major placement, up/left/right/down neighbours
/// clipped to the grid and to `p`, ascending rank order.
fn stencil_dests(rank: usize, p: usize) -> Vec<ProcId> {
    let cols = isqrt(p).max(1);
    let c = rank % cols;
    let mut out = Vec::with_capacity(4);
    if rank >= cols {
        out.push(rank - cols);
    }
    if c > 0 {
        out.push(rank - 1);
    }
    if c + 1 < cols && rank + 1 < p {
        out.push(rank + 1);
    }
    if rank + cols < p {
        out.push(rank + cols);
    }
    out
}

/// Grid neighbours of `rank` on the near-cubic 3D stencil over `p` ranks:
/// side `icbrt(p)`, x-fastest row-major placement, the up-to-six face
/// neighbours clipped to the grid and to `p`, ascending rank order. Ranks
/// beyond the full cube extend the z axis (they keep their ±z links), so
/// every rank of a 2-plus-rank job has at least one neighbour and the
/// relation stays symmetric.
fn stencil3d_dests(rank: usize, p: usize) -> Vec<ProcId> {
    let s = icbrt(p).max(1);
    let x = rank % s;
    let y = (rank / s) % s;
    let mut out = Vec::with_capacity(6);
    if rank >= s * s {
        out.push(rank - s * s);
    }
    if y > 0 {
        out.push(rank - s);
    }
    if x > 0 {
        out.push(rank - 1);
    }
    if x + 1 < s && rank + 1 < p {
        out.push(rank + 1);
    }
    if y + 1 < s && rank + s < p {
        out.push(rank + s);
    }
    if rank + s * s < p {
        out.push(rank + s * s);
    }
    out
}

/// Communication pattern of one parallel job.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Pattern {
    /// Every process sends to every other process (round-robin schedule).
    AllToAll,
    /// Rank 0 sends to ranks 1..P (round-robin); others only receive.
    BcastScatter,
    /// Ranks 1..P send to rank 0; rank 0 only receives.
    GatherReduce,
    /// Rank i sends to rank i+1; the last rank only receives.
    Linear,
    /// Near-square 2D grid halo exchange: every rank sends to its up to four
    /// grid neighbours each round. Symmetric and bounded-degree — the sparse
    /// scale pattern. Not part of [`Pattern::ALL`].
    Stencil2d,
    /// Near-cubic 3D grid halo exchange: every rank sends to its up to six
    /// face neighbours each round. Symmetric and bounded-degree — the
    /// topology-matched workload for 3-D torus sweeps. Not part of
    /// [`Pattern::ALL`].
    Stencil3d,
}

impl Pattern {
    /// The paper's four patterns, in the order its workload tables use them
    /// (the builtin synthetic workloads and the testkit generators draw from
    /// exactly this set).
    pub const ALL: [Pattern; 4] = [
        Pattern::AllToAll,
        Pattern::BcastScatter,
        Pattern::GatherReduce,
        Pattern::Linear,
    ];

    /// Short display name used in reports.
    pub fn name(&self) -> &'static str {
        match self {
            Pattern::AllToAll => "All-to-All",
            Pattern::BcastScatter => "Bcast/Scatter",
            Pattern::GatherReduce => "Gather/Reduce",
            Pattern::Linear => "Linear",
            Pattern::Stencil2d => "2D Stencil",
            Pattern::Stencil3d => "3D Stencil",
        }
    }

    /// Parse a pattern name (accepts several spellings).
    pub fn parse(s: &str) -> Option<Pattern> {
        match s.trim().to_ascii_lowercase().replace(['_', ' '], "-").as_str() {
            "all-to-all" | "alltoall" | "a2a" => Some(Pattern::AllToAll),
            "bcast/scatter" | "bcast-scatter" | "bcast" | "scatter" => Some(Pattern::BcastScatter),
            "gather/reduce" | "gather-reduce" | "gather" | "reduce" => Some(Pattern::GatherReduce),
            "linear" | "ring" | "chain" => Some(Pattern::Linear),
            "2d-stencil" | "stencil-2d" | "stencil2d" | "stencil" | "grid" | "mesh" => {
                Some(Pattern::Stencil2d)
            }
            "3d-stencil" | "stencil-3d" | "stencil3d" | "cube" => Some(Pattern::Stencil3d),
            _ => None,
        }
    }

    /// Does local rank `rank` (0-based) of a `p`-process job send at all?
    pub fn is_sender(&self, rank: usize, p: usize) -> bool {
        match self {
            Pattern::AllToAll => p > 1,
            Pattern::BcastScatter => rank == 0 && p > 1,
            Pattern::GatherReduce => rank != 0,
            Pattern::Linear => rank + 1 < p,
            // Every rank of a 2-plus-rank grid has at least one neighbour.
            Pattern::Stencil2d | Pattern::Stencil3d => p > 1,
        }
    }

    /// Number of distinct destinations for local rank `rank` in a
    /// `p`-process job (the rank's out-degree in the pattern graph).
    pub fn out_degree(&self, rank: usize, p: usize) -> usize {
        if !self.is_sender(rank, p) {
            return 0;
        }
        match self {
            Pattern::AllToAll => p - 1,
            Pattern::BcastScatter => p - 1,
            Pattern::GatherReduce => 1,
            Pattern::Linear => 1,
            Pattern::Stencil2d => stencil_dests(rank, p).len(),
            Pattern::Stencil3d => stencil3d_dests(rank, p).len(),
        }
    }

    /// Adjacency degree of local rank `rank`: distinct partners it sends to
    /// *or* receives from — the `Adj_pi` of paper eq. 2.
    pub fn adjacency(&self, rank: usize, p: usize) -> usize {
        if p <= 1 {
            return 0;
        }
        match self {
            Pattern::AllToAll => p - 1,
            Pattern::BcastScatter | Pattern::GatherReduce => {
                if rank == 0 {
                    p - 1
                } else {
                    1
                }
            }
            Pattern::Linear => {
                if p == 2 {
                    1
                } else if rank == 0 || rank == p - 1 {
                    1
                } else {
                    2
                }
            }
            // Symmetric: partners are exactly the grid neighbours.
            Pattern::Stencil2d => stencil_dests(rank, p).len(),
            Pattern::Stencil3d => stencil3d_dests(rank, p).len(),
        }
    }

    /// Destination (local rank) of the `k`-th message sent by `rank`.
    ///
    /// Returns `None` when `rank` is a pure receiver. For multi-peer patterns
    /// the schedule is round-robin starting at the next higher rank, which
    /// spreads load evenly and is deterministic.
    pub fn dest_of(&self, rank: usize, p: usize, k: u64) -> Option<ProcId> {
        if !self.is_sender(rank, p) {
            return None;
        }
        match self {
            Pattern::AllToAll => {
                let peers = p - 1;
                let off = (k % peers as u64) as usize;
                // Peers in cyclic order after `rank`, skipping self.
                Some((rank + 1 + off) % p)
            }
            Pattern::BcastScatter => {
                let peers = p - 1;
                let off = (k % peers as u64) as usize;
                Some(1 + off)
            }
            Pattern::GatherReduce => Some(0),
            Pattern::Linear => Some(rank + 1),
            Pattern::Stencil2d => {
                let d = stencil_dests(rank, p);
                Some(d[(k % d.len() as u64) as usize])
            }
            Pattern::Stencil3d => {
                let d = stencil3d_dests(rank, p);
                Some(d[(k % d.len() as u64) as usize])
            }
        }
    }

    /// Destination set (local ranks) rank `rank` sends to **each round**.
    ///
    /// Normative send semantics (DESIGN.md §9): a sender emits one message to
    /// every destination in this set per `1/rate` interval, and finishes
    /// after `count` rounds.  This is what makes the paper's loads contend:
    /// an All-to-All process at 100 m/s pushes `(P-1) * 64 KB * 100/s`
    /// through its node's NIC, not `64 KB * 100/s`.
    pub fn dests(&self, rank: usize, p: usize) -> Vec<ProcId> {
        if !self.is_sender(rank, p) {
            return Vec::new();
        }
        match self {
            Pattern::AllToAll => (0..p).filter(|&d| d != rank).collect(),
            Pattern::BcastScatter => (1..p).collect(),
            Pattern::GatherReduce => vec![0],
            Pattern::Linear => vec![rank + 1],
            Pattern::Stencil2d => stencil_dests(rank, p),
            Pattern::Stencil3d => stencil3d_dests(rank, p),
        }
    }

    /// Directed edges `(src, dst)` of the pattern graph over `p` ranks.
    /// Traffic-matrix construction iterates this.
    pub fn edges(&self, p: usize) -> Vec<(ProcId, ProcId)> {
        let mut out = Vec::new();
        for r in 0..p {
            for d in self.dests(r, p) {
                out.push((r, d));
            }
        }
        out
    }

    /// Average adjacency over all ranks (the `Adj_avg` the mapper sorts by).
    pub fn avg_adjacency(&self, p: usize) -> f64 {
        if p == 0 {
            return 0.0;
        }
        let sum: usize = (0..p).map(|r| self.adjacency(r, p)).sum();
        sum as f64 / p as f64
    }

    /// Max adjacency over all ranks (`Adj_max` of eq. 2).
    pub fn max_adjacency(&self, p: usize) -> usize {
        (0..p).map(|r| self.adjacency(r, p)).max().unwrap_or(0)
    }
}

impl std::fmt::Display for Pattern {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips() {
        for p in Pattern::ALL {
            assert_eq!(Pattern::parse(p.name()), Some(p));
        }
        assert_eq!(Pattern::parse("a2a"), Some(Pattern::AllToAll));
        assert_eq!(Pattern::parse("nope"), None);
    }

    #[test]
    fn all_to_all_cycles_all_peers() {
        let p = 5;
        let mut seen = std::collections::BTreeSet::new();
        for k in 0..4 {
            let d = Pattern::AllToAll.dest_of(2, p, k).unwrap();
            assert_ne!(d, 2, "never self-send");
            seen.insert(d);
        }
        assert_eq!(seen.len(), 4, "4 distinct peers in 4 sends");
        // Schedule repeats with period p-1.
        assert_eq!(
            Pattern::AllToAll.dest_of(2, p, 0),
            Pattern::AllToAll.dest_of(2, p, 4)
        );
    }

    #[test]
    fn bcast_root_only_sender() {
        let p = 8;
        assert!(Pattern::BcastScatter.is_sender(0, p));
        for r in 1..p {
            assert!(!Pattern::BcastScatter.is_sender(r, p));
            assert_eq!(Pattern::BcastScatter.dest_of(r, p, 0), None);
        }
        let mut seen = std::collections::BTreeSet::new();
        for k in 0..7 {
            seen.insert(Pattern::BcastScatter.dest_of(0, p, k).unwrap());
        }
        assert_eq!(seen, (1..8).collect());
    }

    #[test]
    fn gather_all_send_to_root() {
        let p = 6;
        assert!(!Pattern::GatherReduce.is_sender(0, p));
        for r in 1..p {
            assert_eq!(Pattern::GatherReduce.dest_of(r, p, 3), Some(0));
        }
    }

    #[test]
    fn linear_chain() {
        let p = 4;
        assert_eq!(Pattern::Linear.dest_of(0, p, 0), Some(1));
        assert_eq!(Pattern::Linear.dest_of(2, p, 9), Some(3));
        assert_eq!(Pattern::Linear.dest_of(3, p, 0), None, "last rank receives only");
    }

    #[test]
    fn adjacency_matches_paper_expectations() {
        // All-to-All 64: everyone adjacent to 63.
        assert_eq!(Pattern::AllToAll.adjacency(10, 64), 63);
        assert_eq!(Pattern::AllToAll.avg_adjacency(64), 63.0);
        assert_eq!(Pattern::AllToAll.max_adjacency(64), 63);
        // Gather 64: root 63, leaves 1 -> avg just under 2.
        assert_eq!(Pattern::GatherReduce.adjacency(0, 64), 63);
        assert_eq!(Pattern::GatherReduce.adjacency(5, 64), 1);
        let avg = Pattern::GatherReduce.avg_adjacency(64);
        assert!(avg > 1.9 && avg < 2.0, "avg {avg}");
        // Linear 64: interior 2, ends 1.
        assert_eq!(Pattern::Linear.adjacency(0, 64), 1);
        assert_eq!(Pattern::Linear.adjacency(63, 64), 1);
        assert_eq!(Pattern::Linear.adjacency(30, 64), 2);
    }

    #[test]
    fn degenerate_sizes() {
        for pat in Pattern::ALL {
            assert_eq!(pat.adjacency(0, 1), 0);
            assert!(!pat.is_sender(0, 1));
            assert_eq!(pat.dest_of(0, 1, 0), None);
        }
    }

    #[test]
    fn dests_match_out_degree_and_edges() {
        for pat in Pattern::ALL {
            for p in [1, 2, 5, 8] {
                let mut edge_count = 0;
                for r in 0..p {
                    let d = pat.dests(r, p);
                    assert_eq!(d.len(), pat.out_degree(r, p), "{pat} rank {r} p {p}");
                    assert!(!d.contains(&r), "no self-sends");
                    edge_count += d.len();
                }
                assert_eq!(pat.edges(p).len(), edge_count);
            }
        }
    }

    #[test]
    fn edges_all_to_all_complete() {
        let e = Pattern::AllToAll.edges(4);
        assert_eq!(e.len(), 12); // 4 * 3 ordered pairs
        let e = Pattern::Linear.edges(4);
        assert_eq!(e, vec![(0, 1), (1, 2), (2, 3)]);
    }

    #[test]
    fn stencil_three_by_three_grid() {
        let p = 9;
        // Center of a 3x3 grid: all four neighbours, ascending.
        assert_eq!(Pattern::Stencil2d.dests(4, p), vec![1, 3, 5, 7]);
        assert_eq!(Pattern::Stencil2d.adjacency(4, p), 4);
        // Corners have two neighbours, edge midpoints three.
        assert_eq!(Pattern::Stencil2d.dests(0, p), vec![1, 3]);
        assert_eq!(Pattern::Stencil2d.dests(8, p), vec![5, 7]);
        assert_eq!(Pattern::Stencil2d.adjacency(1, p), 3);
        // Symmetric: j in dests(i) iff i in dests(j).
        for i in 0..p {
            for j in Pattern::Stencil2d.dests(i, p) {
                assert!(Pattern::Stencil2d.dests(j, p).contains(&i), "{i} <-> {j}");
            }
        }
        // Round-robin schedule cycles the neighbour set.
        assert_eq!(Pattern::Stencil2d.dest_of(4, p, 0), Some(1));
        assert_eq!(Pattern::Stencil2d.dest_of(4, p, 5), Some(3));
    }

    #[test]
    fn stencil_ragged_and_degenerate_sizes() {
        // p = 2: one column, a vertical pair.
        assert_eq!(Pattern::Stencil2d.dests(0, 2), vec![1]);
        assert_eq!(Pattern::Stencil2d.dests(1, 2), vec![0]);
        assert!(!Pattern::Stencil2d.is_sender(0, 1));
        assert_eq!(Pattern::Stencil2d.dest_of(0, 1, 0), None);
        // Ragged grids stay symmetric with everyone connected.
        for p in [2, 3, 5, 7, 10, 12, 17] {
            for r in 0..p {
                let d = Pattern::Stencil2d.dests(r, p);
                assert!(!d.is_empty(), "rank {r} of {p} isolated");
                assert!(!d.contains(&r));
                assert!(d.windows(2).all(|w| w[0] < w[1]), "ascending");
                assert_eq!(d.len(), Pattern::Stencil2d.out_degree(r, p));
                for j in &d {
                    assert!(Pattern::Stencil2d.dests(*j, p).contains(&r));
                }
            }
        }
        // Bounded degree regardless of scale.
        assert_eq!(Pattern::Stencil2d.max_adjacency(4096), 4);
        assert!(Pattern::Stencil2d.avg_adjacency(4096) < 4.0);
    }

    #[test]
    fn stencil3d_three_cubed_grid() {
        let p = 27;
        // Center of a 3x3x3 cube: all six face neighbours, ascending.
        assert_eq!(Pattern::Stencil3d.dests(13, p), vec![4, 10, 12, 14, 16, 22]);
        assert_eq!(Pattern::Stencil3d.adjacency(13, p), 6);
        // Corners have three neighbours.
        assert_eq!(Pattern::Stencil3d.dests(0, p), vec![1, 3, 9]);
        assert_eq!(Pattern::Stencil3d.dests(26, p), vec![17, 23, 25]);
        // Symmetric: j in dests(i) iff i in dests(j).
        for i in 0..p {
            for j in Pattern::Stencil3d.dests(i, p) {
                assert!(Pattern::Stencil3d.dests(j, p).contains(&i), "{i} <-> {j}");
            }
        }
        // Round-robin schedule cycles the neighbour set.
        assert_eq!(Pattern::Stencil3d.dest_of(13, p, 0), Some(4));
        assert_eq!(Pattern::Stencil3d.dest_of(13, p, 7), Some(10));
    }

    #[test]
    fn stencil3d_ragged_and_degenerate_sizes() {
        // p = 2: side 1 — a vertical (z-axis) pair.
        assert_eq!(Pattern::Stencil3d.dests(0, 2), vec![1]);
        assert_eq!(Pattern::Stencil3d.dests(1, 2), vec![0]);
        assert!(!Pattern::Stencil3d.is_sender(0, 1));
        assert_eq!(Pattern::Stencil3d.dest_of(0, 1, 0), None);
        // Ragged grids stay symmetric with everyone connected.
        for p in [2, 3, 5, 7, 10, 12, 17, 30, 64] {
            for r in 0..p {
                let d = Pattern::Stencil3d.dests(r, p);
                assert!(!d.is_empty(), "rank {r} of {p} isolated");
                assert!(!d.contains(&r));
                assert!(d.windows(2).all(|w| w[0] < w[1]), "ascending");
                assert_eq!(d.len(), Pattern::Stencil3d.out_degree(r, p));
                for j in &d {
                    assert!(Pattern::Stencil3d.dests(*j, p).contains(&r));
                }
            }
        }
        // Bounded degree regardless of scale.
        assert_eq!(Pattern::Stencil3d.max_adjacency(4096), 6);
        assert!(Pattern::Stencil3d.avg_adjacency(4096) < 6.0);
    }

    #[test]
    fn stencil3d_parse_spellings() {
        for s in ["3d-stencil", "stencil-3d", "stencil3d", "3D Stencil", "cube"] {
            assert_eq!(Pattern::parse(s), Some(Pattern::Stencil3d), "{s}");
        }
        assert_eq!(Pattern::parse(Pattern::Stencil3d.name()), Some(Pattern::Stencil3d));
        // The cubic patterns never shadow the paper set or the 2D stencil.
        assert!(!Pattern::ALL.contains(&Pattern::Stencil3d));
        assert_eq!(Pattern::parse("stencil"), Some(Pattern::Stencil2d));
    }

    #[test]
    fn stencil_parse_spellings() {
        for s in ["stencil", "stencil2d", "2d-stencil", "2D Stencil", "grid", "mesh"] {
            assert_eq!(Pattern::parse(s), Some(Pattern::Stencil2d), "{s}");
        }
        assert_eq!(Pattern::parse(Pattern::Stencil2d.name()), Some(Pattern::Stencil2d));
    }

    #[test]
    fn out_degree_consistent_with_dest_of() {
        for pat in Pattern::ALL {
            let p = 7;
            for r in 0..p {
                let deg = pat.out_degree(r, p);
                let mut seen = std::collections::BTreeSet::new();
                for k in 0..32 {
                    if let Some(d) = pat.dest_of(r, p, k) {
                        seen.insert(d);
                    }
                }
                assert_eq!(seen.len(), deg, "{pat} rank {r}");
            }
        }
    }
}
