//! Jobs and workloads (paper §5.2 Tables 2–5, §5.3 Tables 6–9).
//!
//! A [`JobSpec`] is one parallel job: `procs` processes plus one or more
//! communication [`FlowSpec`]s (synthetic jobs have exactly one flow; NPB
//! jobs from [`crate::model::npb`] may have several, e.g. an all-to-all
//! transpose phase plus a neighbour-exchange phase).
//!
//! A [`Workload`] is the batch of jobs the mapper places at once; global
//! process ids are assigned contiguously per job, in job order.

use crate::error::{Error, Result};
use crate::model::pattern::Pattern;
use crate::units::{fmt_bytes, Bytes, MsgPerSec, KB, MB};

/// Index of a job within its workload.
pub type JobId = usize;
/// Global process index within a workload (across all jobs).
pub type ProcId = usize;

/// Message-size classes of the paper's step 1 (§4): "large messages (1MB or
/// higher), medium messages (2KB to 1MB), and small messages (2KB or less)".
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SizeClass {
    /// ≥ 1 MB — mapped first.
    Large,
    /// (2 KB, 1 MB) — mapped second.
    Medium,
    /// ≤ 2 KB — mapped last.
    Small,
}

impl SizeClass {
    /// Classify a message length per the paper's boundaries.
    pub fn of(bytes: Bytes) -> SizeClass {
        if bytes >= MB {
            SizeClass::Large
        } else if bytes > 2 * KB {
            SizeClass::Medium
        } else {
            SizeClass::Small
        }
    }

    /// Mapping order (paper step 1/4/6): Large, then Medium, then Small.
    pub const ORDER: [SizeClass; 3] = [SizeClass::Large, SizeClass::Medium, SizeClass::Small];
}

/// One communication flow of a job: a pattern at a message size and rate,
/// with a per-sender message budget.
#[derive(Debug, Clone, PartialEq)]
pub struct FlowSpec {
    /// Communication pattern.
    pub pattern: Pattern,
    /// Message length in bytes (paper tables: 64KB / 2MB).
    pub msg_bytes: Bytes,
    /// Send rate per sending process, messages per second.
    pub rate: MsgPerSec,
    /// Number of messages each sending process transmits before finishing.
    pub count: u64,
}

impl FlowSpec {
    /// Construct a flow.
    pub fn new(pattern: Pattern, msg_bytes: Bytes, rate: MsgPerSec, count: u64) -> Self {
        FlowSpec { pattern, msg_bytes, rate, count }
    }
}

/// One parallel job.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    /// Display name (e.g. `"All-to-All 64KB@100m/s"` or `"IS.C.32"`).
    pub name: String,
    /// Number of parallel processes.
    pub procs: usize,
    /// Communication flows (≥ 1).
    pub flows: Vec<FlowSpec>,
}

impl JobSpec {
    /// Single-flow synthetic job (rows of paper Tables 2–5).
    pub fn synthetic(
        pattern: Pattern,
        procs: usize,
        msg_bytes: Bytes,
        rate: MsgPerSec,
        count: u64,
    ) -> Self {
        JobSpec {
            name: format!("{} {}@{}m/s", pattern.name(), fmt_bytes(msg_bytes), rate),
            procs,
            flows: vec![FlowSpec::new(pattern, msg_bytes, rate, count)],
        }
    }

    /// Largest message length over all flows — the paper's tie-break:
    /// "In such cases largest message length is considered for action."
    pub fn largest_msg(&self) -> Bytes {
        self.flows.iter().map(|f| f.msg_bytes).max().unwrap_or(0)
    }

    /// Size class of the job (by largest message).
    pub fn size_class(&self) -> SizeClass {
        SizeClass::of(self.largest_msg())
    }

    /// Validate: ≥1 process, ≥1 flow, positive sizes/rates.
    pub fn validate(&self) -> Result<()> {
        if self.procs == 0 {
            return Err(Error::spec(format!("job {:?}: zero processes", self.name)));
        }
        if self.flows.is_empty() {
            return Err(Error::spec(format!("job {:?}: no flows", self.name)));
        }
        for f in &self.flows {
            if f.msg_bytes == 0 {
                return Err(Error::spec(format!("job {:?}: zero-byte messages", self.name)));
            }
            if !(f.rate > 0.0) {
                return Err(Error::spec(format!("job {:?}: non-positive rate", self.name)));
            }
        }
        Ok(())
    }

    /// Total bytes this job will ever push through the system (round send
    /// semantics: each round a sender emits one message per destination).
    pub fn total_bytes(&self) -> u128 {
        self.flows
            .iter()
            .map(|f| {
                let msgs_per_round: usize =
                    (0..self.procs).map(|r| f.pattern.out_degree(r, self.procs)).sum();
                msgs_per_round as u128 * f.count as u128 * f.msg_bytes as u128
            })
            .sum()
    }
}

/// A batch of jobs mapped and simulated together.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Workload {
    /// Display name (e.g. `"synt_workload_3"`).
    pub name: String,
    /// Jobs, in table order. `JobId` indexes this vector.
    pub jobs: Vec<JobSpec>,
}

impl Workload {
    /// Build and validate.
    pub fn new(name: impl Into<String>, jobs: Vec<JobSpec>) -> Result<Self> {
        let w = Workload { name: name.into(), jobs };
        w.validate()?;
        Ok(w)
    }

    /// Validate all jobs.
    pub fn validate(&self) -> Result<()> {
        if self.jobs.is_empty() {
            return Err(Error::spec(format!("workload {:?} has no jobs", self.name)));
        }
        for j in &self.jobs {
            j.validate()?;
        }
        Ok(())
    }

    /// Total process count over all jobs.
    pub fn total_procs(&self) -> usize {
        self.jobs.iter().map(|j| j.procs).sum()
    }

    /// Global id of rank 0 of `job`.
    pub fn job_offset(&self, job: JobId) -> ProcId {
        self.jobs[..job].iter().map(|j| j.procs).sum()
    }

    /// Global process-id range of `job`.
    pub fn procs_of_job(&self, job: JobId) -> std::ops::Range<ProcId> {
        let off = self.job_offset(job);
        off..off + self.jobs[job].procs
    }

    /// Map a global process id back to `(job, local rank)`.
    pub fn job_of_proc(&self, proc: ProcId) -> (JobId, usize) {
        let mut off = 0;
        for (j, job) in self.jobs.iter().enumerate() {
            if proc < off + job.procs {
                return (j, proc - off);
            }
            off += job.procs;
        }
        panic!("proc id {proc} out of range ({} total)", self.total_procs());
    }

    // ------------------------------------------------------------------
    // Paper synthetic workloads (Tables 2–5).
    // ------------------------------------------------------------------

    /// Table 2: 4 jobs × 64 procs, 64 KB @ 100 m/s, 2000 messages.
    pub fn synt_workload_1() -> Self {
        let jobs = Pattern::ALL
            .iter()
            .map(|&p| JobSpec::synthetic(p, 64, 64 * KB, 100.0, 2000))
            .collect();
        Workload { name: "synt_workload_1".into(), jobs }
    }

    /// Table 3: 4 jobs × 64 procs, 2 MB @ 10 m/s, 2000 messages.
    pub fn synt_workload_2() -> Self {
        let jobs = Pattern::ALL
            .iter()
            .map(|&p| JobSpec::synthetic(p, 64, 2 * MB, 10.0, 2000))
            .collect();
        Workload { name: "synt_workload_2".into(), jobs }
    }

    /// Table 4: 8 jobs × 32 procs; jobs 0–3 at 2 MB @ 10 m/s, jobs 4–7 at
    /// 64 KB @ 10 m/s.
    pub fn synt_workload_3() -> Self {
        let mut jobs: Vec<JobSpec> = Pattern::ALL
            .iter()
            .map(|&p| JobSpec::synthetic(p, 32, 2 * MB, 10.0, 2000))
            .collect();
        jobs.extend(
            Pattern::ALL
                .iter()
                .map(|&p| JobSpec::synthetic(p, 32, 64 * KB, 10.0, 2000)),
        );
        Workload { name: "synt_workload_3".into(), jobs }
    }

    /// Table 5: 8 jobs × 24 procs; same size/rate split as Table 4.
    pub fn synt_workload_4() -> Self {
        let mut jobs: Vec<JobSpec> = Pattern::ALL
            .iter()
            .map(|&p| JobSpec::synthetic(p, 24, 2 * MB, 10.0, 2000))
            .collect();
        jobs.extend(
            Pattern::ALL
                .iter()
                .map(|&p| JobSpec::synthetic(p, 24, 64 * KB, 10.0, 2000)),
        );
        Workload { name: "synt_workload_4".into(), jobs }
    }

    /// All four synthetic workloads in paper order.
    pub fn all_synthetic() -> Vec<Self> {
        vec![
            Self::synt_workload_1(),
            Self::synt_workload_2(),
            Self::synt_workload_3(),
            Self::synt_workload_4(),
        ]
    }

    /// Look a builtin workload up by name (`synt1..4`, `real1..4`).
    pub fn builtin(name: &str) -> Result<Self> {
        use crate::model::npb;
        match name.trim().to_ascii_lowercase().as_str() {
            "synt1" | "synt_workload_1" => Ok(Self::synt_workload_1()),
            "synt2" | "synt_workload_2" => Ok(Self::synt_workload_2()),
            "synt3" | "synt_workload_3" => Ok(Self::synt_workload_3()),
            "synt4" | "synt_workload_4" => Ok(Self::synt_workload_4()),
            "real1" | "real_workload_1" => Ok(npb::real_workload_1()),
            "real2" | "real_workload_2" => Ok(npb::real_workload_2()),
            "real3" | "real_workload_3" => Ok(npb::real_workload_3()),
            "real4" | "real_workload_4" => Ok(npb::real_workload_4()),
            other => Err(Error::usage(format!(
                "unknown builtin workload {other:?} (expected synt1..4 or real1..4)"
            ))),
        }
    }

    /// Names of all builtin workloads.
    pub fn builtin_names() -> [&'static str; 8] {
        ["synt1", "synt2", "synt3", "synt4", "real1", "real2", "real3", "real4"]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_class_boundaries_match_paper() {
        assert_eq!(SizeClass::of(MB), SizeClass::Large);
        assert_eq!(SizeClass::of(2 * MB), SizeClass::Large);
        assert_eq!(SizeClass::of(MB - 1), SizeClass::Medium);
        assert_eq!(SizeClass::of(2 * KB + 1), SizeClass::Medium);
        assert_eq!(SizeClass::of(2 * KB), SizeClass::Small);
        assert_eq!(SizeClass::of(1), SizeClass::Small);
    }

    #[test]
    fn synt1_matches_table2() {
        let w = Workload::synt_workload_1();
        assert_eq!(w.jobs.len(), 4);
        assert_eq!(w.total_procs(), 256);
        for (i, pat) in Pattern::ALL.iter().enumerate() {
            assert_eq!(w.jobs[i].procs, 64);
            assert_eq!(w.jobs[i].flows[0].pattern, *pat);
            assert_eq!(w.jobs[i].flows[0].msg_bytes, 64_000);
            assert_eq!(w.jobs[i].flows[0].rate, 100.0);
            assert_eq!(w.jobs[i].flows[0].count, 2000);
            assert_eq!(w.jobs[i].size_class(), SizeClass::Medium);
        }
    }

    #[test]
    fn synt2_is_large_class() {
        let w = Workload::synt_workload_2();
        assert!(w.jobs.iter().all(|j| j.size_class() == SizeClass::Large));
        assert_eq!(w.total_procs(), 256);
    }

    #[test]
    fn synt3_synt4_mixed_classes() {
        let w3 = Workload::synt_workload_3();
        assert_eq!(w3.jobs.len(), 8);
        assert_eq!(w3.total_procs(), 256);
        assert!(w3.jobs[..4].iter().all(|j| j.size_class() == SizeClass::Large));
        assert!(w3.jobs[4..].iter().all(|j| j.size_class() == SizeClass::Medium));
        let w4 = Workload::synt_workload_4();
        assert_eq!(w4.total_procs(), 192);
    }

    #[test]
    fn proc_id_round_trip() {
        let w = Workload::synt_workload_3();
        for p in 0..w.total_procs() {
            let (j, r) = w.job_of_proc(p);
            assert!(w.procs_of_job(j).contains(&p));
            assert_eq!(w.job_offset(j) + r, p);
        }
    }

    #[test]
    fn total_bytes_counts_round_fanout() {
        // Gather/Reduce 4 procs: 3 senders x 1 dest x 10 rounds x 1000 B.
        let j = JobSpec::synthetic(Pattern::GatherReduce, 4, 1000, 1.0, 10);
        assert_eq!(j.total_bytes(), 30_000);
        // Bcast: root sends to 3 peers per round.
        let j = JobSpec::synthetic(Pattern::BcastScatter, 4, 1000, 1.0, 10);
        assert_eq!(j.total_bytes(), 30_000);
        // AllToAll: 4 senders x 3 dests x 10 rounds.
        let j = JobSpec::synthetic(Pattern::AllToAll, 4, 1000, 1.0, 10);
        assert_eq!(j.total_bytes(), 120_000);
    }

    #[test]
    fn builtin_lookup() {
        for name in Workload::builtin_names() {
            let w = Workload::builtin(name).unwrap();
            w.validate().unwrap();
        }
        assert!(Workload::builtin("bogus").is_err());
    }

    #[test]
    fn validation_rejects_bad_jobs() {
        let mut j = JobSpec::synthetic(Pattern::Linear, 4, 1000, 1.0, 10);
        j.procs = 0;
        assert!(j.validate().is_err());
        let mut j = JobSpec::synthetic(Pattern::Linear, 4, 1000, 1.0, 10);
        j.flows[0].msg_bytes = 0;
        assert!(j.validate().is_err());
        let mut j = JobSpec::synthetic(Pattern::Linear, 4, 1000, 1.0, 10);
        j.flows.clear();
        assert!(j.validate().is_err());
    }
}
