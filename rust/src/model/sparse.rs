//! Sparse-row traffic — the canonical communication artifact.
//!
//! Every pattern the paper evaluates (and every stencil/ring beyond it) is
//! sparse: a process talks to a handful of neighbours, so the dense
//! [`TrafficMatrix`] wastes O(P²) memory and forces every hot walk to scan
//! P entries per row just to skip zeros. [`SparseTraffic`] stores CSR rows
//! of `(dst, rate)` nonzeros plus the transpose (in-edges per destination)
//! and per-process tx/rx aggregates, so
//!
//! * workload memory is O(nnz), not O(P²),
//! * per-row walks ([`SparseTraffic::pairs`]) visit exactly the nonzero
//!   partners, in ascending partner order,
//! * row/column sums are precomputed once.
//!
//! ## Dense equivalence, bit for bit
//!
//! The dense hot walks all iterate `j` ascending and guard each side with
//! `v > 0.0` independently. [`SparseTraffic::pairs`] merges the out-row and
//! the in-column by two pointers, yielding `(j, out, in)` for every `j`
//! where either direction is nonzero, ascending, with `0.0` for an absent
//! side — the *same* visit sequence with the *same* values, so any
//! accumulation over it produces bit-identical floats. Aggregates are built
//! in the dense accumulation order (row-major for `tx` and for the
//! transpose scatter that feeds `rx`), and adding the skipped zeros to a
//! non-negative running sum is a bitwise no-op, so [`SparseTraffic::tx_rate`]
//! / [`SparseTraffic::rx_rate`] equal the dense row/column sums exactly.
//! Only [`SparseTraffic::demand`] (tx + rx, two separate sums) differs in
//! *order* from the dense interleaved sum — exact anyway for the
//! integer-valued rates of every builtin and testkit workload.
//! `tests/property_invariants.rs` proves the round-trip and the ledger
//! equivalences.
//!
//! The dense [`TrafficMatrix`] remains as the degenerate/interop case:
//! verification recomputes, the AOT artifact padder, and CLI reporting use
//! [`SparseTraffic::to_dense`] / [`SparseTraffic::from_dense`] round-trips.

use crate::model::traffic::TrafficMatrix;
use crate::model::workload::{JobSpec, ProcId, Workload};

/// CSR traffic over `n` processes: out-rows, the transpose (in-rows), and
/// per-process tx/rx byte-rate aggregates. Immutable after construction;
/// only strictly positive rates are stored.
#[derive(Debug, Clone, PartialEq)]
pub struct SparseTraffic {
    n: usize,
    /// Out-row offsets, `n + 1` entries.
    row_off: Vec<usize>,
    /// Destinations, ascending within each out-row.
    cols: Vec<ProcId>,
    /// Rates parallel to `cols` (bytes/sec, all > 0).
    rates: Vec<f64>,
    /// In-row (transpose) offsets, `n + 1` entries.
    in_off: Vec<usize>,
    /// Sources, ascending within each in-row.
    srcs: Vec<ProcId>,
    /// Rates parallel to `srcs`.
    in_rates: Vec<f64>,
    /// Row sums (total send rate per process).
    tx: Vec<f64>,
    /// Column sums (total receive rate per process).
    rx: Vec<f64>,
}

impl SparseTraffic {
    /// Empty traffic over `n` processes (no flows).
    pub fn zeros(n: usize) -> Self {
        Self::from_sorted_entries(n, &[])
    }

    /// Sparse traffic of a single job (indices are local ranks).
    ///
    /// Flow contributions accumulate in the same per-edge encounter order
    /// as [`TrafficMatrix::of_job`], so each stored rate is bit-identical
    /// to the dense cell.
    pub fn of_job(job: &JobSpec) -> Self {
        let mut triples = Vec::new();
        for flow in &job.flows {
            let per_edge = flow.msg_bytes as f64 * flow.rate;
            for (src, dst) in flow.pattern.edges(job.procs) {
                triples.push((src, dst, per_edge));
            }
        }
        Self::from_triples(job.procs, triples)
    }

    /// Sparse traffic of a whole workload (global proc ids, block diagonal
    /// in job order). Counts toward [`TrafficMatrix::workload_builds`] —
    /// it is the same one-build-per-workload artifact, in sparse form.
    pub fn of_workload(w: &Workload) -> Self {
        crate::model::traffic::note_workload_build();
        let mut triples = Vec::new();
        for (jid, job) in w.jobs.iter().enumerate() {
            let off = w.job_offset(jid);
            for flow in &job.flows {
                let per_edge = flow.msg_bytes as f64 * flow.rate;
                for (src, dst) in flow.pattern.edges(job.procs) {
                    triples.push((off + src, off + dst, per_edge));
                }
            }
        }
        Self::from_triples(w.total_procs(), triples)
    }

    /// Sparse view of a dense matrix: keeps exactly the strictly positive
    /// cells. Round-trips with [`Self::to_dense`] whenever the dense matrix
    /// has no negative entries (rates never are).
    pub fn from_dense(t: &TrafficMatrix) -> Self {
        let n = t.len();
        let mut entries = Vec::new();
        for i in 0..n {
            for (j, &v) in t.row(i).iter().enumerate() {
                if v > 0.0 {
                    entries.push((i, j, v));
                }
            }
        }
        Self::from_sorted_entries(n, &entries)
    }

    /// Densify (interop/verification paths: full-scorer recomputes, the AOT
    /// artifact padder, CLI reporting).
    pub fn to_dense(&self) -> TrafficMatrix {
        let mut t = TrafficMatrix::zeros(self.n);
        for i in 0..self.n {
            let (cols, rates) = self.out_row(i);
            for (&j, &v) in cols.iter().zip(rates) {
                t.add(i, j, v);
            }
        }
        t
    }

    /// Accumulate duplicate `(i, j)` triples in encounter order (stable
    /// sort), drop non-positive results, build the CSR structures.
    fn from_triples(n: usize, mut triples: Vec<(ProcId, ProcId, f64)>) -> Self {
        triples.sort_by_key(|&(i, j, _)| (i, j));
        let mut entries: Vec<(ProcId, ProcId, f64)> = Vec::with_capacity(triples.len());
        for (i, j, v) in triples {
            match entries.last_mut() {
                Some(e) if e.0 == i && e.1 == j => e.2 += v,
                _ => entries.push((i, j, v)),
            }
        }
        entries.retain(|&(_, _, v)| v > 0.0);
        Self::from_sorted_entries(n, &entries)
    }

    /// Build from entries sorted by `(row, col)`, unique, all > 0.
    fn from_sorted_entries(n: usize, entries: &[(ProcId, ProcId, f64)]) -> Self {
        let nnz = entries.len();
        let mut row_off = vec![0usize; n + 1];
        let mut in_off = vec![0usize; n + 1];
        for &(i, j, _) in entries {
            row_off[i + 1] += 1;
            in_off[j + 1] += 1;
        }
        for v in 1..=n {
            row_off[v] += row_off[v - 1];
            in_off[v] += in_off[v - 1];
        }
        let mut cols = Vec::with_capacity(nnz);
        let mut rates = Vec::with_capacity(nnz);
        let mut srcs = vec![0 as ProcId; nnz];
        let mut in_rates = vec![0.0f64; nnz];
        let mut tx = vec![0.0f64; n];
        let mut rx = vec![0.0f64; n];
        let mut cursor = in_off.clone();
        // One row-major pass: fills the out-CSR in order, scatters the
        // transpose (sources arrive ascending per in-row because the scan
        // is row-major), and accumulates tx/rx in exactly the dense
        // row-/column-sum order.
        for &(i, j, v) in entries {
            cols.push(j);
            rates.push(v);
            let slot = cursor[j];
            srcs[slot] = i;
            in_rates[slot] = v;
            cursor[j] += 1;
            tx[i] += v;
            rx[j] += v;
        }
        SparseTraffic { n, row_off, cols, rates, in_off, srcs, in_rates, tx, rx }
    }

    /// Process count.
    pub fn len(&self) -> usize {
        self.n
    }

    /// True when tracking zero processes.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Number of stored (strictly positive) directed entries.
    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    /// Rate from `i` to `j` (0.0 when not stored). O(log nnz-per-row).
    pub fn get(&self, i: ProcId, j: ProcId) -> f64 {
        let (cols, rates) = self.out_row(i);
        match cols.binary_search(&j) {
            Ok(k) => rates[k],
            Err(_) => 0.0,
        }
    }

    /// Out-row of `i`: destinations (ascending) and their rates.
    #[inline]
    pub fn out_row(&self, i: ProcId) -> (&[ProcId], &[f64]) {
        let (a, b) = (self.row_off[i], self.row_off[i + 1]);
        (&self.cols[a..b], &self.rates[a..b])
    }

    /// In-row of `i`: sources (ascending) and their rates.
    #[inline]
    pub fn in_row(&self, i: ProcId) -> (&[ProcId], &[f64]) {
        let (a, b) = (self.in_off[i], self.in_off[i + 1]);
        (&self.srcs[a..b], &self.in_rates[a..b])
    }

    /// Total send rate of `i` (bytes/sec) — bit-equal to the dense row sum.
    #[inline]
    pub fn tx_rate(&self, i: ProcId) -> f64 {
        self.tx[i]
    }

    /// Total receive rate of `i` (bytes/sec) — bit-equal to the dense
    /// column sum.
    #[inline]
    pub fn rx_rate(&self, i: ProcId) -> f64 {
        self.rx[i]
    }

    /// Communication demand of `i` (paper eq. 1: tx + rx). Equal to
    /// [`TrafficMatrix::demand`] — exactly for integer-valued rates, up to
    /// FP associativity otherwise (the dense sum interleaves directions).
    pub fn demand(&self, i: ProcId) -> f64 {
        self.tx[i] + self.rx[i]
    }

    /// Symmetric volume between `i` and `j` (`i->j` plus `j->i`, in that
    /// operand order — bitwise equal to [`TrafficMatrix::between`]).
    pub fn between(&self, i: ProcId, j: ProcId) -> f64 {
        self.get(i, j) + self.get(j, i)
    }

    /// Merged walk over the nonzero partners of `p`: yields
    /// `(j, out, in)` = `(j, rate p->j, rate j->p)` for every `j` with
    /// traffic in either direction, ascending `j`, `0.0` for an absent
    /// side. This is the sparse replacement for the dense
    /// `for j in 0..P { row[j] / get(j, p) }` hot walks — same visit
    /// sequence, same values, O(nnz-per-row) instead of O(P).
    pub fn pairs(&self, p: ProcId) -> PairIter<'_> {
        let (oc, or_) = self.out_row(p);
        let (ic, ir) = self.in_row(p);
        PairIter { oc, or_, ic, ir, oi: 0, ii: 0 }
    }

    /// Adjacency degree of `i` (`Adj_pi` of eq. 2): distinct partners with
    /// traffic in either direction, self excluded.
    pub fn adjacency(&self, i: ProcId) -> usize {
        self.pairs(i).filter(|&(j, _, _)| j != i).count()
    }

    /// Average adjacency over all processes (`Adj_avg`).
    pub fn avg_adjacency(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let s: usize = (0..self.n).map(|i| self.adjacency(i)).sum();
        s as f64 / self.n as f64
    }

    /// Max adjacency over all processes (`Adj_max`), 0 for empty.
    pub fn max_adjacency(&self) -> usize {
        (0..self.n).map(|i| self.adjacency(i)).max().unwrap_or(0)
    }

    /// Partners of `i` sorted by descending symmetric volume, rank
    /// ascending on ties — same order and bit-identical volumes as
    /// [`TrafficMatrix::partners_by_volume`].
    pub fn partners_by_volume(&self, i: ProcId) -> Vec<(ProcId, f64)> {
        let mut v: Vec<(ProcId, f64)> = self
            .pairs(i)
            .filter(|&(j, _, _)| j != i)
            .map(|(j, out, inc)| (j, out + inc))
            .filter(|&(_, w)| w > 0.0)
            .collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        v
    }

    /// Total traffic volume (bytes/sec) — bit-equal to the dense row-major
    /// sum over all cells.
    pub fn total(&self) -> f64 {
        self.rates.iter().sum()
    }

    /// Heap bytes held by this artifact — the number the scale bench
    /// asserts stays below the dense `P² × 8` wall.
    pub fn artifact_bytes(&self) -> usize {
        use std::mem::size_of;
        (self.row_off.len() + self.in_off.len()) * size_of::<usize>()
            + (self.cols.len() + self.srcs.len()) * size_of::<ProcId>()
            + (self.rates.len() + self.in_rates.len() + self.tx.len() + self.rx.len())
                * size_of::<f64>()
    }
}

/// Two-pointer merge over one process's out-row and in-row — see
/// [`SparseTraffic::pairs`].
#[derive(Debug, Clone)]
pub struct PairIter<'a> {
    oc: &'a [ProcId],
    or_: &'a [f64],
    ic: &'a [ProcId],
    ir: &'a [f64],
    oi: usize,
    ii: usize,
}

impl Iterator for PairIter<'_> {
    /// `(partner, out rate, in rate)`.
    type Item = (ProcId, f64, f64);

    fn next(&mut self) -> Option<(ProcId, f64, f64)> {
        let o = self.oc.get(self.oi).copied();
        let i = self.ic.get(self.ii).copied();
        match (o, i) {
            (None, None) => None,
            (Some(j), None) => {
                let out = self.or_[self.oi];
                self.oi += 1;
                Some((j, out, 0.0))
            }
            (None, Some(j)) => {
                let inc = self.ir[self.ii];
                self.ii += 1;
                Some((j, 0.0, inc))
            }
            (Some(jo), Some(ji)) => {
                if jo < ji {
                    let out = self.or_[self.oi];
                    self.oi += 1;
                    Some((jo, out, 0.0))
                } else if ji < jo {
                    let inc = self.ir[self.ii];
                    self.ii += 1;
                    Some((ji, 0.0, inc))
                } else {
                    let (out, inc) = (self.or_[self.oi], self.ir[self.ii]);
                    self.oi += 1;
                    self.ii += 1;
                    Some((jo, out, inc))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::pattern::Pattern;
    use crate::model::workload::JobSpec;

    fn jobs() -> Vec<JobSpec> {
        vec![
            JobSpec::synthetic(Pattern::AllToAll, 6, 64_000, 100.0, 2000),
            JobSpec::synthetic(Pattern::GatherReduce, 5, 1000, 2.0, 10),
            JobSpec::synthetic(Pattern::Linear, 4, 2_000, 5.0, 50),
            JobSpec::synthetic(Pattern::BcastScatter, 3, 500, 3.0, 7),
            JobSpec::synthetic(Pattern::Stencil2d, 9, 4_000, 2.0, 64),
        ]
    }

    #[test]
    fn of_job_equals_dense_from_dense() {
        for job in jobs() {
            let dense = TrafficMatrix::of_job(&job);
            let sparse = SparseTraffic::of_job(&job);
            assert_eq!(sparse, SparseTraffic::from_dense(&dense), "{}", job.name);
            assert_eq!(sparse.to_dense(), dense, "{}", job.name);
        }
    }

    #[test]
    fn round_trip_is_exact() {
        let w = Workload::new("t", jobs()).unwrap();
        let dense = TrafficMatrix::of_workload(&w);
        let sparse = SparseTraffic::of_workload(&w);
        assert_eq!(sparse.to_dense(), dense);
        assert_eq!(SparseTraffic::from_dense(&dense), sparse);
        assert_eq!(sparse.len(), dense.len());
        let stored = (0..dense.len())
            .flat_map(|i| (0..dense.len()).map(move |j| (i, j)))
            .filter(|&(i, j)| dense.get(i, j) > 0.0)
            .count();
        assert_eq!(sparse.nnz(), stored);
    }

    #[test]
    fn queries_match_dense_bitwise() {
        let w = Workload::new("t", jobs()).unwrap();
        let dense = TrafficMatrix::of_workload(&w);
        let sparse = SparseTraffic::of_workload(&w);
        assert_eq!(sparse.total(), dense.total());
        assert_eq!(sparse.max_adjacency(), dense.max_adjacency());
        assert_eq!(sparse.avg_adjacency(), dense.avg_adjacency());
        for i in 0..dense.len() {
            assert_eq!(sparse.tx_rate(i), dense.row(i).iter().sum::<f64>());
            let col: f64 = (0..dense.len()).map(|j| dense.get(j, i)).sum();
            assert_eq!(sparse.rx_rate(i), col);
            // Integer-valued builtin rates: split demand is exact.
            assert_eq!(sparse.demand(i), dense.demand(i));
            assert_eq!(sparse.adjacency(i), dense.adjacency(i));
            assert_eq!(sparse.partners_by_volume(i), dense.partners_by_volume(i));
            for j in 0..dense.len() {
                assert_eq!(sparse.get(i, j), dense.get(i, j));
                assert_eq!(sparse.between(i, j), dense.between(i, j));
            }
        }
    }

    #[test]
    fn pairs_visits_exactly_the_dense_guarded_walk() {
        let w = Workload::new("t", jobs()).unwrap();
        let dense = TrafficMatrix::of_workload(&w);
        let sparse = SparseTraffic::of_workload(&w);
        for p in 0..dense.len() {
            let want: Vec<(usize, f64, f64)> = (0..dense.len())
                .map(|j| (j, dense.get(p, j), dense.get(j, p)))
                .filter(|&(_, out, inc)| out > 0.0 || inc > 0.0)
                .collect();
            let got: Vec<(usize, f64, f64)> = sparse.pairs(p).collect();
            assert_eq!(got, want, "proc {p}");
        }
    }

    #[test]
    fn duplicate_flows_accumulate_like_dense() {
        let job = JobSpec {
            name: "mix".into(),
            procs: 3,
            flows: vec![
                crate::model::workload::FlowSpec::new(Pattern::Linear, 1000, 1.0, 5),
                crate::model::workload::FlowSpec::new(Pattern::Linear, 1000, 2.0, 5),
            ],
        };
        let t = SparseTraffic::of_job(&job);
        assert_eq!(t.get(0, 1), 3000.0);
        assert_eq!(t.nnz(), 2);
    }

    #[test]
    fn zeros_and_empty() {
        let z = SparseTraffic::zeros(4);
        assert_eq!(z.len(), 4);
        assert_eq!(z.nnz(), 0);
        assert_eq!(z.get(1, 2), 0.0);
        assert_eq!(z.adjacency(0), 0);
        assert_eq!(z.to_dense(), TrafficMatrix::zeros(4));
        assert!(z.pairs(0).next().is_none());
        let e = SparseTraffic::zeros(0);
        assert!(e.is_empty());
        assert_eq!(e.avg_adjacency(), 0.0);
        assert_eq!(e.max_adjacency(), 0);
    }

    #[test]
    fn artifact_bytes_scale_with_nnz_not_p_squared() {
        let job = JobSpec::synthetic(Pattern::Stencil2d, 1024, 4_000, 2.0, 64);
        let t = SparseTraffic::of_job(&job);
        let dense_bytes = 1024 * 1024 * std::mem::size_of::<f64>();
        assert!(t.nnz() < 5 * 1024);
        assert!(
            t.artifact_bytes() < dense_bytes / 4,
            "sparse {} vs dense {}",
            t.artifact_bytes(),
            dense_bytes
        );
    }
}
