//! Text spec format for custom clusters and workloads.
//!
//! Offline image ⇒ no serde; the format is a deliberately small line-based
//! `key=value` syntax:
//!
//! ```text
//! # cluster definition (optional; paper cluster if absent)
//! cluster nodes=16 sockets=4 cores=4 mem_bw=4GB nic_bw=1GB cache_bw=8GB \
//!         cache_max=1MB remote_pct=110 switch_ns=100
//!
//! # one line per job — synthetic…
//! job procs=64 pattern=all-to-all size=64KB rate=100m/s count=2000
//! # …or NPB shorthand
//! job npb=IS.C.32
//! ```
//!
//! `#` starts a comment; a trailing `\` continues a line.

use crate::error::{Error, Result};
use crate::model::npb;
use crate::model::pattern::Pattern;
use crate::model::topology::ClusterSpec;
use crate::model::workload::{JobSpec, Workload};
use crate::units::{parse_bytes, parse_rate};

/// Parsed spec file: a cluster (defaulting to the paper's) and a workload.
#[derive(Debug, Clone)]
pub struct SpecFile {
    /// Cluster description.
    pub cluster: ClusterSpec,
    /// Workload to map/simulate.
    pub workload: Workload,
}

/// Split a physical file into logical lines (comments stripped, `\`
/// continuations joined).
fn logical_lines(text: &str) -> Vec<(usize, String)> {
    let mut out: Vec<(usize, String)> = Vec::new();
    let mut pending: Option<(usize, String)> = None;
    for (lineno, raw) in text.lines().enumerate() {
        let stripped = match raw.find('#') {
            Some(pos) => &raw[..pos],
            None => raw,
        };
        let stripped = stripped.trim_end();
        let (cont, body) = match stripped.strip_suffix('\\') {
            Some(b) => (true, b.trim_end()),
            None => (false, stripped),
        };
        match pending.take() {
            Some((start, mut acc)) => {
                acc.push(' ');
                acc.push_str(body.trim_start());
                if cont {
                    pending = Some((start, acc));
                } else {
                    out.push((start, acc));
                }
            }
            None => {
                if body.trim().is_empty() && !cont {
                    continue;
                }
                if cont {
                    pending = Some((lineno + 1, body.trim_start().to_string()));
                } else {
                    out.push((lineno + 1, body.trim().to_string()));
                }
            }
        }
    }
    if let Some((start, acc)) = pending {
        out.push((start, acc));
    }
    out.retain(|(_, l)| !l.is_empty());
    out
}

/// Parse `key=value` tokens of one logical line.
fn kv_pairs(line: &str) -> Result<Vec<(String, String)>> {
    line.split_whitespace()
        .map(|tok| {
            let (k, v) = tok
                .split_once('=')
                .ok_or_else(|| Error::spec(format!("expected key=value, got {tok:?}")))?;
            Ok((k.to_ascii_lowercase(), v.to_string()))
        })
        .collect()
}

fn parse_cluster_line(pairs: &[(String, String)]) -> Result<ClusterSpec> {
    let mut c = ClusterSpec::paper_cluster();
    for (k, v) in pairs {
        match k.as_str() {
            "nodes" => c.nodes = v.parse().map_err(|_| Error::spec("bad nodes"))?,
            "sockets" => {
                c.sockets_per_node = v.parse().map_err(|_| Error::spec("bad sockets"))?
            }
            "cores" => {
                c.cores_per_socket = v.parse().map_err(|_| Error::spec("bad cores"))?
            }
            "mem_bw" => c.mem_bw = parse_bytes(v)?,
            "nic_bw" => c.nic_bw = parse_bytes(v)?,
            "cache_bw" => c.cache_bw = parse_bytes(v)?,
            "cache_max" => c.cache_max_msg = parse_bytes(v)?,
            "remote_pct" => {
                c.remote_mem_pct = v.parse().map_err(|_| Error::spec("bad remote_pct"))?
            }
            "switch_ns" => {
                c.switch_latency = v.parse().map_err(|_| Error::spec("bad switch_ns"))?
            }
            other => return Err(Error::spec(format!("unknown cluster key {other:?}"))),
        }
    }
    c.validate()?;
    Ok(c)
}

fn parse_job_line(pairs: &[(String, String)]) -> Result<JobSpec> {
    // NPB shorthand takes the whole line.
    if let Some((_, v)) = pairs.iter().find(|(k, _)| k == "npb") {
        if pairs.len() != 1 {
            return Err(Error::spec("npb= jobs take no other keys"));
        }
        return npb::parse_job(v);
    }
    let mut procs: Option<usize> = None;
    let mut pattern: Option<Pattern> = None;
    let mut size: Option<u64> = None;
    let mut rate: Option<f64> = None;
    let mut count: u64 = 2000;
    let mut name: Option<String> = None;
    for (k, v) in pairs {
        match k.as_str() {
            "procs" => procs = Some(v.parse().map_err(|_| Error::spec("bad procs"))?),
            "pattern" => {
                pattern = Some(
                    Pattern::parse(v)
                        .ok_or_else(|| Error::spec(format!("unknown pattern {v:?}")))?,
                )
            }
            "size" => size = Some(parse_bytes(v)?),
            "rate" => rate = Some(parse_rate(v)?),
            "count" => count = v.parse().map_err(|_| Error::spec("bad count"))?,
            "name" => name = Some(v.clone()),
            other => return Err(Error::spec(format!("unknown job key {other:?}"))),
        }
    }
    let procs = procs.ok_or_else(|| Error::spec("job missing procs="))?;
    let pattern = pattern.ok_or_else(|| Error::spec("job missing pattern="))?;
    let size = size.ok_or_else(|| Error::spec("job missing size="))?;
    let rate = rate.ok_or_else(|| Error::spec("job missing rate="))?;
    let mut job = JobSpec::synthetic(pattern, procs, size, rate, count);
    if let Some(n) = name {
        job.name = n;
    }
    job.validate()?;
    Ok(job)
}

/// Parse a full spec document.
pub fn parse(text: &str) -> Result<SpecFile> {
    let mut cluster = ClusterSpec::paper_cluster();
    let mut saw_cluster = false;
    let mut jobs = Vec::new();
    let mut name = "custom".to_string();
    for (lineno, line) in logical_lines(text) {
        let (verb, rest) = match line.split_once(char::is_whitespace) {
            Some((v, r)) => (v, r),
            None => (line.as_str(), ""),
        };
        let result = match verb {
            "cluster" => {
                if saw_cluster {
                    Err(Error::spec("duplicate cluster line"))
                } else {
                    saw_cluster = true;
                    kv_pairs(rest).and_then(|p| parse_cluster_line(&p).map(|c| cluster = c))
                }
            }
            "job" => kv_pairs(rest).and_then(|p| parse_job_line(&p).map(|j| jobs.push(j))),
            "workload" => {
                name = rest.trim().to_string();
                Ok(())
            }
            other => Err(Error::spec(format!("unknown verb {other:?}"))),
        };
        result.map_err(|e| Error::spec(format!("line {lineno}: {e}")))?;
    }
    let workload = Workload::new(name, jobs)?;
    Ok(SpecFile { cluster, workload })
}

/// Load and parse a spec file from disk.
pub fn load(path: &std::path::Path) -> Result<SpecFile> {
    let text = std::fs::read_to_string(path)?;
    parse(&text)
}

/// Serialize a workload back to the spec format (round-trips synthetic
/// single-flow jobs; NPB jobs are emitted with their `npb=` shorthand when
/// recognizable by name).
pub fn to_text(cluster: &ClusterSpec, w: &Workload) -> String {
    let mut out = String::new();
    out.push_str(&format!("workload {}\n", w.name));
    out.push_str(&format!(
        "cluster nodes={} sockets={} cores={} mem_bw={}B nic_bw={}B cache_bw={}B cache_max={}B remote_pct={} switch_ns={}\n",
        cluster.nodes,
        cluster.sockets_per_node,
        cluster.cores_per_socket,
        cluster.mem_bw,
        cluster.nic_bw,
        cluster.cache_bw,
        cluster.cache_max_msg,
        cluster.remote_mem_pct,
        cluster.switch_latency,
    ));
    for j in &w.jobs {
        let looks_npb = j.name.matches('.').count() == 2 && npb::parse_job(&j.name).is_ok();
        if looks_npb {
            out.push_str(&format!("job npb={}\n", j.name));
        } else {
            // Multi-flow non-NPB jobs serialize one line per flow (same name).
            for f in &j.flows {
                out.push_str(&format!(
                    "job procs={} pattern={} size={}B rate={}m/s count={}\n",
                    j.procs,
                    f.pattern.name().replace(' ', "-"),
                    f.msg_bytes,
                    f.rate,
                    f.count
                ));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::units::{GB, KB};

    #[test]
    fn parse_minimal_workload() {
        let s = parse("job procs=8 pattern=linear size=64KB rate=10m/s count=100").unwrap();
        assert_eq!(s.cluster, ClusterSpec::paper_cluster());
        assert_eq!(s.workload.jobs.len(), 1);
        assert_eq!(s.workload.jobs[0].procs, 8);
        assert_eq!(s.workload.jobs[0].flows[0].msg_bytes, 64 * KB);
    }

    #[test]
    fn parse_cluster_overrides() {
        let s = parse(
            "cluster nodes=4 sockets=2 cores=2 nic_bw=2GB\n\
             job procs=4 pattern=a2a size=1KB rate=1m/s",
        )
        .unwrap();
        assert_eq!(s.cluster.nodes, 4);
        assert_eq!(s.cluster.nic_bw, 2 * GB);
        // Unspecified keys keep paper defaults.
        assert_eq!(s.cluster.mem_bw, 4 * GB);
    }

    #[test]
    fn parse_npb_shorthand() {
        let s = parse("job npb=IS.C.32\njob npb=FT.B.16").unwrap();
        assert_eq!(s.workload.jobs.len(), 2);
        assert_eq!(s.workload.jobs[0].name, "IS.C.32");
        assert_eq!(s.workload.jobs[1].procs, 16);
    }

    #[test]
    fn comments_and_continuations() {
        let s = parse(
            "# a comment\n\
             workload demo\n\
             job procs=4 pattern=linear \\\n\
                 size=2KB rate=5m/s count=7   # trailing comment\n",
        )
        .unwrap();
        assert_eq!(s.workload.name, "demo");
        assert_eq!(s.workload.jobs[0].flows[0].count, 7);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse("job procs=4 pattern=linear size=2KB rate=5m/s\nbogus line here")
            .unwrap_err();
        assert!(err.to_string().contains("line 2"), "{err}");
        let err = parse("job procs=4 pattern=wat size=2KB rate=5m/s").unwrap_err();
        assert!(err.to_string().contains("line 1"), "{err}");
    }

    #[test]
    fn missing_required_keys_rejected() {
        assert!(parse("job pattern=linear size=2KB rate=5m/s").is_err());
        assert!(parse("job procs=4 size=2KB rate=5m/s").is_err());
        assert!(parse("job procs=4 pattern=linear rate=5m/s").is_err());
        assert!(parse("job procs=4 pattern=linear size=2KB").is_err());
    }

    #[test]
    fn round_trip_through_text() {
        let w = Workload::synt_workload_1();
        let text = to_text(&ClusterSpec::paper_cluster(), &w);
        let s = parse(&text).unwrap();
        assert_eq!(s.workload.jobs.len(), 4);
        assert_eq!(s.workload.name, "synt_workload_1");
        for (a, b) in s.workload.jobs.iter().zip(&w.jobs) {
            assert_eq!(a.procs, b.procs);
            assert_eq!(a.flows[0].pattern, b.flows[0].pattern);
            assert_eq!(a.flows[0].msg_bytes, b.flows[0].msg_bytes);
        }
    }

    #[test]
    fn npb_round_trip() {
        let w = crate::model::npb::real_workload_4();
        let text = to_text(&ClusterSpec::paper_cluster(), &w);
        let s = parse(&text).unwrap();
        assert_eq!(s.workload.jobs.len(), 4);
        assert_eq!(s.workload.jobs[0].name, "SP.C.25");
    }

    #[test]
    fn duplicate_cluster_rejected() {
        assert!(parse("cluster nodes=2\ncluster nodes=3\njob npb=EP.B.32").is_err());
    }
}
