//! Traffic matrices — the Application Graph (AG) of the mapping literature.
//!
//! `T[i][j]` is the steady-state byte rate (bytes/sec) from process `i` to
//! process `j`, built from the job flow specs under the round send semantics
//! of DESIGN.md §9 (`rate` messages to **each** destination per second).
//!
//! The dense form is the **degenerate/interop case**: the canonical hot-path
//! artifact is [`crate::model::sparse::SparseTraffic`] (CSR nonzero rows),
//! which round-trips this matrix exactly. Dense stays in use where a full
//! P×P view is genuinely wanted:
//! * the AOT cost model (the Rust side pads this matrix into the artifact),
//! * full-scorer verification recomputes and CLI reporting,
//! * small interop/test fixtures.

use std::sync::OnceLock;

use crate::model::workload::{JobId, JobSpec, ProcId, Workload};
use crate::obs::metrics::{self, Counter};

/// Registry counter `traffic.workload_builds`: process-wide count of
/// [`TrafficMatrix::of_workload`] constructions.
///
/// The full workload matrix is the single most expensive model artifact
/// (O(P²)); the [`crate::ctx::MapCtx`] layer exists to build it exactly once
/// per workload. This counter is the instrumentation that lets tests *prove*
/// that guarantee (one increment per workload per sweep) instead of assuming
/// it — see `tests/mapctx_sweep.rs`.
fn builds_counter() -> Counter {
    static C: OnceLock<Counter> = OnceLock::new();
    *C.get_or_init(|| metrics::counter("traffic.workload_builds"))
}

/// Count one full-workload traffic construction. Shared by
/// [`TrafficMatrix::of_workload`] and
/// [`crate::model::sparse::SparseTraffic::of_workload`] — dense or sparse,
/// it is the same once-per-workload artifact the counter guards.
pub(crate) fn note_workload_build() {
    builds_counter().inc();
}

/// Dense square traffic matrix in bytes/sec.
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficMatrix {
    n: usize,
    /// Row-major `n x n` rates.
    data: Vec<f64>,
}

impl TrafficMatrix {
    /// Zero matrix over `n` processes.
    pub fn zeros(n: usize) -> Self {
        TrafficMatrix { n, data: vec![0.0; n * n] }
    }

    /// Traffic matrix of a single job (indices are local ranks).
    pub fn of_job(job: &JobSpec) -> Self {
        let mut t = Self::zeros(job.procs);
        for flow in &job.flows {
            let per_edge = flow.msg_bytes as f64 * flow.rate;
            for (src, dst) in flow.pattern.edges(job.procs) {
                t.add(src, dst, per_edge);
            }
        }
        t
    }

    /// Traffic matrix of a whole workload (indices are global proc ids;
    /// jobs never communicate with each other, so the matrix is block
    /// diagonal in job order).
    pub fn of_workload(w: &Workload) -> Self {
        note_workload_build();
        let mut t = Self::zeros(w.total_procs());
        for (jid, job) in w.jobs.iter().enumerate() {
            let off = w.job_offset(jid);
            // Accumulate each flow edge directly at its global offset —
            // same adds in the same order as a per-job build, without the
            // intermediate O(procs²) matrix and copy.
            for flow in &job.flows {
                let per_edge = flow.msg_bytes as f64 * flow.rate;
                for (src, dst) in flow.pattern.edges(job.procs) {
                    t.add(off + src, off + dst, per_edge);
                }
            }
        }
        t
    }

    /// Process-wide number of [`Self::of_workload`] constructions so far.
    ///
    /// Monotone counter for the one-build-per-workload guarantee of
    /// [`crate::ctx::MapCtx`]; tests snapshot it around a sweep and assert
    /// the delta. Per-job ([`Self::of_job`]) builds are not counted. Thin
    /// shim over the `traffic.workload_builds` registry counter — new code
    /// should prefer [`crate::obs::testkit::counter_guard`] deltas.
    pub fn workload_builds() -> u64 {
        builds_counter().get()
    }

    /// Matrix dimension (process count).
    pub fn len(&self) -> usize {
        self.n
    }

    /// True when tracking zero processes.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Rate from `i` to `j` (bytes/sec).
    #[inline]
    pub fn get(&self, i: ProcId, j: ProcId) -> f64 {
        self.data[i * self.n + j]
    }

    /// Add to the `i -> j` rate.
    #[inline]
    pub fn add(&mut self, i: ProcId, j: ProcId, v: f64) {
        self.data[i * self.n + j] += v;
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: ProcId) -> &[f64] {
        &self.data[i * self.n..(i + 1) * self.n]
    }

    /// Raw row-major data (for padding into the AOT artifact).
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Symmetric volume between `i` and `j` (`i->j` plus `j->i`).
    #[inline]
    pub fn between(&self, i: ProcId, j: ProcId) -> f64 {
        self.get(i, j) + self.get(j, i)
    }

    /// Communication demand of process `i` — paper eq. 1, counted in both
    /// directions so pure receivers (e.g. a Gather root) rank high too.
    pub fn demand(&self, i: ProcId) -> f64 {
        let mut d = 0.0;
        for j in 0..self.n {
            d += self.get(i, j) + self.get(j, i);
        }
        d
    }

    /// Adjacency degree of `i`: distinct partners with nonzero traffic in
    /// either direction (`Adj_pi` of eq. 2).
    pub fn adjacency(&self, i: ProcId) -> usize {
        (0..self.n)
            .filter(|&j| j != i && (self.get(i, j) > 0.0 || self.get(j, i) > 0.0))
            .count()
    }

    /// Partners of `i` sorted by descending symmetric volume (paper step
    /// 3.8: "adjacent processes of A are sorted based on the communication
    /// demands between A and them").
    pub fn partners_by_volume(&self, i: ProcId) -> Vec<(ProcId, f64)> {
        let mut v: Vec<(ProcId, f64)> = (0..self.n)
            .filter(|&j| j != i)
            .map(|j| (j, self.between(i, j)))
            .filter(|&(_, w)| w > 0.0)
            .collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        v
    }

    /// Total traffic volume (bytes/sec) over all pairs.
    pub fn total(&self) -> f64 {
        self.data.iter().sum()
    }

    /// Average adjacency over all processes (`Adj_avg`).
    pub fn avg_adjacency(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let s: usize = (0..self.n).map(|i| self.adjacency(i)).sum();
        s as f64 / self.n as f64
    }

    /// Max adjacency over all processes (`Adj_max`), 0 for empty.
    pub fn max_adjacency(&self) -> usize {
        (0..self.n).map(|i| self.adjacency(i)).max().unwrap_or(0)
    }
}

/// Per-job views over a workload's traffic, in the canonical sparse form.
#[derive(Debug, Clone)]
pub struct JobTraffic {
    /// Owning job.
    pub job: JobId,
    /// Local-rank sparse traffic.
    pub matrix: crate::model::sparse::SparseTraffic,
}

impl JobTraffic {
    /// Build per-job traffic for the whole workload.
    pub fn for_workload(w: &Workload) -> Vec<JobTraffic> {
        w.jobs
            .iter()
            .enumerate()
            .map(|(jid, job)| JobTraffic {
                job: jid,
                matrix: crate::model::sparse::SparseTraffic::of_job(job),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::pattern::Pattern;
    use crate::model::workload::JobSpec;
    use crate::units::KB;

    fn a2a_job(p: usize) -> JobSpec {
        JobSpec::synthetic(Pattern::AllToAll, p, 64 * KB, 100.0, 2000)
    }

    #[test]
    fn all_to_all_uniform_rates() {
        let t = TrafficMatrix::of_job(&a2a_job(4));
        let want = 64_000.0 * 100.0; // bytes * rate per edge
        for i in 0..4 {
            assert_eq!(t.get(i, i), 0.0);
            for j in 0..4 {
                if i != j {
                    assert_eq!(t.get(i, j), want);
                }
            }
        }
        assert_eq!(t.total(), want * 12.0);
    }

    #[test]
    fn demand_symmetric_both_directions() {
        let j = JobSpec::synthetic(Pattern::GatherReduce, 4, 1000, 2.0, 10);
        let t = TrafficMatrix::of_job(&j);
        // Root receives 3 * 2000 B/s; senders each send 2000 B/s.
        assert_eq!(t.demand(0), 6000.0);
        assert_eq!(t.demand(1), 2000.0);
        assert_eq!(t.adjacency(0), 3);
        assert_eq!(t.adjacency(1), 1);
    }

    #[test]
    fn adjacency_matches_pattern() {
        for pat in Pattern::ALL {
            let j = JobSpec::synthetic(pat, 8, 1000, 1.0, 10);
            let t = TrafficMatrix::of_job(&j);
            for r in 0..8 {
                assert_eq!(t.adjacency(r), pat.adjacency(r, 8), "{pat} rank {r}");
            }
            assert!((t.avg_adjacency() - pat.avg_adjacency(8)).abs() < 1e-12);
            assert_eq!(t.max_adjacency(), pat.max_adjacency(8));
        }
    }

    #[test]
    fn workload_matrix_block_diagonal() {
        let w = Workload::new(
            "t",
            vec![a2a_job(3), JobSpec::synthetic(Pattern::Linear, 3, 1000, 1.0, 5)],
        )
        .unwrap();
        let t = TrafficMatrix::of_workload(&w);
        assert_eq!(t.len(), 6);
        // No cross-job traffic.
        for i in 0..3 {
            for j in 3..6 {
                assert_eq!(t.get(i, j), 0.0);
                assert_eq!(t.get(j, i), 0.0);
            }
        }
        // Linear block present at the offset.
        assert!(t.get(3, 4) > 0.0);
        assert!(t.get(4, 5) > 0.0);
        assert_eq!(t.get(5, 3), 0.0);
    }

    #[test]
    fn partners_sorted_descending() {
        let mut t = TrafficMatrix::zeros(4);
        t.add(0, 1, 5.0);
        t.add(0, 2, 10.0);
        t.add(3, 0, 1.0);
        let p = t.partners_by_volume(0);
        assert_eq!(p.len(), 3);
        assert_eq!(p[0].0, 2);
        assert_eq!(p[1].0, 1);
        assert_eq!(p[2].0, 3);
    }

    #[test]
    fn conservation_total_equals_sum_of_demands_halved() {
        let w = Workload::synt_workload_1();
        let t = TrafficMatrix::of_workload(&w);
        let demand_sum: f64 = (0..t.len()).map(|i| t.demand(i)).sum();
        // Each byte counted once as send demand, once as receive demand.
        assert!((demand_sum - 2.0 * t.total()).abs() < 1e-3 * t.total());
    }

    #[test]
    fn multi_flow_accumulates() {
        let job = JobSpec {
            name: "mix".into(),
            procs: 3,
            flows: vec![
                crate::model::workload::FlowSpec::new(Pattern::Linear, 1000, 1.0, 5),
                crate::model::workload::FlowSpec::new(Pattern::Linear, 1000, 2.0, 5),
            ],
        };
        let t = TrafficMatrix::of_job(&job);
        assert_eq!(t.get(0, 1), 3000.0); // 1000*1 + 1000*2
    }
}
