//! End-to-end driver — proves the layers compose on a real workload
//! (the EXPERIMENTS.md §E2E run):
//!
//!  1. Pick the cost-model scorer: the AOT JAX/Pallas artifact via PJRT when
//!     built with the `pjrt` feature and `artifacts/` exists, else the
//!     pure-Rust native scorer (bit-compatible semantics, cross-checked).
//!  2. Layer 3: map the paper's Table 4 workload with all four strategies.
//!  3. Use the cost model *on the request path* to refine the Blocked
//!     placement (paper §7 future work) — candidates are scored through
//!     the O(P) `LoadLedger`; the full scorer runs only to seed + verify.
//!  4. Simulate everything on the Table 1 cluster and report the paper's
//!     headline metric, including the refined placement.
//!
//! ```sh
//! cargo run --release --example e2e_driver
//! ```

use nicmap::coordinator::refine::refine;
use nicmap::coordinator::MapperKind;
use nicmap::cost::Scorer;
use nicmap::ctx::MapCtx;
use nicmap::harness::Metric;
use nicmap::model::topology::ClusterSpec;
use nicmap::model::workload::Workload;
use nicmap::report::figure::bar_chart;
use nicmap::report::table::Table;
use nicmap::runtime::NativeScorer;
use nicmap::sim::{simulate, SimConfig};

fn main() -> nicmap::Result<()> {
    #[cfg(feature = "pjrt")]
    {
        use nicmap::runtime::{ArtifactStore, PjrtScorer};
        match ArtifactStore::open_default() {
            Ok(store) => {
                println!(
                    "[1] scorer: PJRT platform {} — {} artifacts in manifest",
                    store.platform(),
                    store.metas().len()
                );
                let scorer = PjrtScorer::new(&store);
                return drive(&scorer);
            }
            Err(e) => eprintln!("note: {e}; driving with the native scorer"),
        }
    }
    println!("[1] scorer: native (pure-Rust cost model)");
    drive(&NativeScorer)
}

fn drive(scorer: &dyn Scorer) -> nicmap::Result<()> {
    let cluster = ClusterSpec::paper_cluster();
    let w = Workload::builtin("synt4")?; // the paper's 91 %-gain workload
    // Shared artifact layer: one ctx build covers every mapper, the
    // refinement stage, and the scorer cross-check below.
    let ctx = MapCtx::build(&w);
    // Dense view for the scorer cross-check and the dense-path refine
    // helper below; the mapping steps themselves stay on the sparse ctx.
    let traffic = ctx.dense_traffic();
    println!("=== nicmap end-to-end driver ===");
    println!("cluster:  {}", cluster.summary());
    println!("workload: {} ({} jobs, {} procs)\n", w.name, w.jobs.len(), w.total_procs());

    // Cross-check the active scorer against the pure-Rust oracle.
    let probe = MapperKind::Cyclic.build().map(&ctx, &cluster)?;
    let a = scorer.score(traffic, &probe, &cluster)?;
    let b = NativeScorer.score(traffic, &probe, &cluster)?;
    let max_rel = a
        .nic_tx
        .iter()
        .zip(&b.nic_tx)
        .map(|(x, y)| (x - y).abs() / y.abs().max(1.0))
        .fold(0.0f64, f64::max);
    println!("    scorer vs Rust oracle: max rel err {max_rel:.2e} (must be < 1e-4)");
    assert!(max_rel < 1e-4);

    // --- Step 2: map with all strategies. --------------------------------
    println!("\n[2] mapping with B/C/D/N…");
    let mut placements = Vec::new();
    for kind in MapperKind::PAPER {
        let t0 = std::time::Instant::now();
        let p = kind.build().map(&ctx, &cluster)?;
        println!(
            "    {:<8} {:>8.2?}  nodes used: {}",
            kind.name(),
            t0.elapsed(),
            p.nodes_used(&cluster)
        );
        placements.push((kind.name().to_string(), p));
    }

    // --- Step 3: the cost model on the hot path — refine Blocked. --------
    println!("\n[3] refining Blocked with the cost model…");
    let blocked = placements[0].1.clone();
    let t0 = std::time::Instant::now();
    let rep = refine(scorer, traffic, &blocked, &w, &cluster, 12)?;
    println!(
        "    objective {:.3e} -> {:.3e} | {} moves | {} full scorer passes \
         | {} O(P) ledger evals | {:.2?}",
        rep.before,
        rep.after,
        rep.moves,
        rep.evaluations,
        rep.delta_evals,
        t0.elapsed()
    );
    placements.push(("B+refine".into(), rep.placement));

    // --- Step 4: simulate everything. ------------------------------------
    println!("\n[4] simulating on the Table 1 cluster…");
    let cfg = SimConfig::default();
    let mut rows = Vec::new();
    let mut table = Table::new(vec![
        "strategy",
        "waiting (ms)",
        "workload finish (s)",
        "total finish (s)",
        "events",
    ]);
    for (name, p) in &placements {
        let r = simulate(&w, p, &cluster, &cfg)?;
        table.row(vec![
            name.clone(),
            format!("{:.3e}", r.waiting_ms()),
            format!("{:.2}", r.workload_finish_s()),
            format!("{:.2}", r.total_finish_s()),
            r.events.to_string(),
        ]);
        rows.push((name.clone(), r.waiting_ms()));
    }
    print!("{table}");
    println!();
    let title = format!("{} — {}", w.name, Metric::WaitingMs.label());
    println!("{}", bar_chart(&title, &rows, 40));

    let new = rows.iter().find(|(n, _)| n == "New").unwrap().1;
    let best_other = rows
        .iter()
        .filter(|(n, _)| n != "New")
        .map(|(_, v)| *v)
        .fold(f64::INFINITY, f64::min);
    println!(
        "headline: New strategy gain vs best other = {:+.1}%  (paper: ≈91% here)",
        (best_other - new) / best_other * 100.0
    );
    println!(
        "refinement: Blocked {:.3e} -> B+refine {:.3e} ms waiting",
        rows[0].1,
        rows.iter().find(|(n, _)| n == "B+refine").unwrap().1
    );
    Ok(())
}
