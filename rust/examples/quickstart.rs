//! Quickstart: map a workload with the paper's strategy and simulate it.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use nicmap::coordinator::MapperKind;
use nicmap::model::topology::ClusterSpec;
use nicmap::model::workload::Workload;
use nicmap::sim::{simulate, SimConfig};

fn main() -> nicmap::Result<()> {
    // The paper's simulated platform: 16 nodes x 4 sockets x 4 cores,
    // 1 GB/s InfiniBand NIC per node (Table 1).
    let cluster = ClusterSpec::paper_cluster();
    println!("cluster: {}", cluster.summary());

    // Synthetic workload 3 (Table 4): eight 32-process jobs, half sending
    // 2 MB messages, half 64 KB.
    let workload = Workload::builtin("synt3")?;
    println!("workload: {} ({} processes)", workload.name, workload.total_procs());

    // Map with the paper's threshold strategy, then with Cyclic for contrast.
    for kind in [MapperKind::New, MapperKind::Cyclic] {
        let placement = kind.build().map_workload(&workload, &cluster)?;
        let report = simulate(&workload, &placement, &cluster, &SimConfig::default())?;
        println!(
            "{:<7}: waiting {:>13.3e} ms | workload finish {:>8.2} s | {} messages",
            kind.name(),
            report.waiting_ms(),
            report.workload_finish_s(),
            report.delivered,
        );
    }
    println!("(lower waiting time is better — the New strategy caps the number of");
    println!(" heavy inter-node communicators per node via the eq. 2 threshold)");
    Ok(())
}
