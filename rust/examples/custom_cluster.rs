//! Custom-cluster example: how the strategy's value changes with the
//! core-per-NIC ratio — the exact trend the paper's introduction argues
//! (cores per node grow, NICs stay at 1).
//!
//! ```sh
//! cargo run --release --example custom_cluster
//! ```

use nicmap::coordinator::MapperKind;
use nicmap::model::pattern::Pattern;
use nicmap::model::topology::ClusterSpec;
use nicmap::model::workload::{JobSpec, Workload};
use nicmap::report::figure::gain_pct;
use nicmap::report::table::Table;
use nicmap::sim::{simulate, SimConfig};
use nicmap::units::MB;

fn main() -> nicmap::Result<()> {
    // Same total core count (256), same NIC, growing node fatness.
    let shapes = [
        (32, 2, 4), // 32 nodes x 8  cores
        (16, 4, 4), // paper: 16 nodes x 16 cores
        (8, 4, 8),  // 8  nodes x 32 cores
        (4, 8, 8),  // 4  nodes x 64 cores
    ];
    let mut table = Table::new(vec![
        "cluster",
        "cores/NIC",
        "Blocked (ms)",
        "Cyclic (ms)",
        "New (ms)",
        "New gain%",
    ]);
    for (nodes, sockets, cores) in shapes {
        let cluster = ClusterSpec {
            nodes,
            sockets_per_node: sockets,
            cores_per_socket: cores,
            ..ClusterSpec::paper_cluster()
        };
        let w = Workload::new(
            "mix",
            vec![
                JobSpec::synthetic(Pattern::AllToAll, 48, 2 * MB, 10.0, 200),
                JobSpec::synthetic(Pattern::Linear, 48, 2 * MB, 10.0, 200),
                JobSpec::synthetic(Pattern::GatherReduce, 48, 2 * MB, 10.0, 200),
            ],
        )?;
        let mut vals = Vec::new();
        for kind in [MapperKind::Blocked, MapperKind::Cyclic, MapperKind::New] {
            let p = kind.build().map_workload(&w, &cluster)?;
            let r = simulate(&w, &p, &cluster, &SimConfig::default())?;
            vals.push(r.waiting_ms());
        }
        let best_other = vals[0].min(vals[1]);
        table.row(vec![
            format!("{}x{}x{}", nodes, sockets, cores),
            cluster.cores_per_node().to_string(),
            format!("{:.3e}", vals[0]),
            format!("{:.3e}", vals[1]),
            format!("{:.3e}", vals[2]),
            format!("{:+.1}", gain_pct(vals[2], best_other)),
        ]);
    }
    println!("Fixed 144-process mixed workload, 256 cores total, 1 GB/s NIC per node:");
    print!("{table}");
    println!("\nFatter nodes => more cores per NIC => contention-aware mapping matters more.");
    Ok(())
}
