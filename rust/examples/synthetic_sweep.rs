//! The paper's motivation, §1: NIC contention grows with message size and
//! rate. Sweep an all-to-all job across message sizes and watch the
//! Blocked/Cyclic crossover — and the New strategy tracking the winner on
//! both sides.
//!
//! ```sh
//! cargo run --release --example synthetic_sweep
//! ```

use nicmap::coordinator::MapperKind;
use nicmap::model::pattern::Pattern;
use nicmap::model::topology::ClusterSpec;
use nicmap::model::workload::{JobSpec, Workload};
use nicmap::report::table::Table;
use nicmap::sim::{simulate, SimConfig};
use nicmap::units::{fmt_bytes, KB, MB};

fn main() -> nicmap::Result<()> {
    let cluster = ClusterSpec::paper_cluster();
    let sizes = [2 * KB, 64 * KB, 512 * KB, MB, 2 * MB];
    let rate = 10.0;
    let rounds = 300;

    let mut table =
        Table::new(vec!["msg size", "Blocked (ms)", "Cyclic (ms)", "New (ms)", "winner"]);
    for &size in &sizes {
        // One 64-proc all-to-all job + one 64-proc linear job sharing the
        // cluster — the mix is what makes placement matter.
        let w = Workload::new(
            "sweep",
            vec![
                JobSpec::synthetic(Pattern::AllToAll, 64, size, rate, rounds),
                JobSpec::synthetic(Pattern::Linear, 64, size, rate, rounds),
            ],
        )?;
        let mut vals = Vec::new();
        for kind in [MapperKind::Blocked, MapperKind::Cyclic, MapperKind::New] {
            let p = kind.build().map_workload(&w, &cluster)?;
            let r = simulate(&w, &p, &cluster, &SimConfig::default())?;
            vals.push(r.waiting_ms());
        }
        let winner = if vals[0] < vals[1] { "Blocked" } else { "Cyclic" };
        table.row(vec![
            fmt_bytes(size),
            format!("{:.3e}", vals[0]),
            format!("{:.3e}", vals[1]),
            format!("{:.3e}", vals[2]),
            winner.to_string(),
        ]);
    }
    println!("All-to-All(64) + Linear(64) at {rate} rounds/s, {rounds} rounds:");
    print!("{table}");
    println!("\nNew should track (or beat) the better of Blocked/Cyclic at every size.");
    Ok(())
}
