//! Real-workload example: the paper's Table 6 NPB mix with per-job
//! breakdown — which benchmarks suffer under which mapping.
//!
//! ```sh
//! cargo run --release --example npb_cluster
//! ```

use nicmap::coordinator::MapperKind;
use nicmap::model::topology::ClusterSpec;
use nicmap::model::workload::Workload;
use nicmap::report::table::Table;
use nicmap::sim::{simulate, SimConfig};

fn main() -> nicmap::Result<()> {
    let cluster = ClusterSpec::paper_cluster();
    let w = Workload::builtin("real1")?; // paper Table 6
    println!("workload {} — {} jobs / {} processes\n", w.name, w.jobs.len(), w.total_procs());

    let blocked = MapperKind::Blocked.build().map_workload(&w, &cluster)?;
    let new = MapperKind::New.build().map_workload(&w, &cluster)?;
    let rb = simulate(&w, &blocked, &cluster, &SimConfig::default())?;
    let rn = simulate(&w, &new, &cluster, &SimConfig::default())?;

    let mut table = Table::new(vec![
        "job",
        "wait Blocked (ms)",
        "wait New (ms)",
        "finish B (s)",
        "finish N (s)",
        "nodes B",
        "nodes N",
    ]);
    for (jid, job) in w.jobs.iter().enumerate() {
        let nodes_used = |p: &nicmap::coordinator::Placement| {
            p.job_node_counts(&w, jid, &cluster).iter().filter(|&&c| c > 0).count()
        };
        table.row(vec![
            job.name.clone(),
            format!("{:.2e}", rb.jobs[jid].wait_ns as f64 / 1e6),
            format!("{:.2e}", rn.jobs[jid].wait_ns as f64 / 1e6),
            format!("{:.2}", rb.jobs[jid].finish_ns as f64 / 1e9),
            format!("{:.2}", rn.jobs[jid].finish_ns as f64 / 1e9),
            nodes_used(&blocked).to_string(),
            nodes_used(&new).to_string(),
        ]);
    }
    print!("{table}");
    println!(
        "\ntotals: Blocked {:.3e} ms vs New {:.3e} ms waiting ({:.0}x)",
        rb.waiting_ms(),
        rn.waiting_ms(),
        rb.waiting_ms() / rn.waiting_ms().max(1e-9)
    );
    println!("(IS/FT all-to-all jobs spread via the threshold; CG/BT neighbour jobs stay packed)");
    Ok(())
}
